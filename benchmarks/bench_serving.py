"""Serving throughput: continuous batching vs the static-batch baseline.

Workload: a burst of ``2 x slots`` variable-output-length requests (a
2x-oversubscribed stream). The static baseline packs ``slots`` requests
per batch and must decode every batch until its LONGEST member finishes
— short requests burn slots as padding. The engine evicts finished
requests and admits queued ones into the freed slots, so steady-state
decode stays at full batch width. Useful-token throughput is the metric;
per-request outputs are checked token-identical between the two paths
(both are greedy over the same weights).

Arch coverage: every slot-servable cache family — dense attention
(qwen), pure SSM (mamba2), parallel attention+SSM hybrid (hymba) and
MLA dense+MoE (deepseek). ``--eos-id`` marks a stop token on every
request: the engine recycles a slot the moment it fires (the static
baseline cannot — its batch still decodes to the longest member, and
its post-EOS tokens are discarded), so EOS-heavy workloads widen the
engine's useful-throughput lead.

Variants: fp32 weights and ``wbits 8`` packed-int8 serving (the engine
consumes PackedTensor weights directly, dequant-on-read; the baseline
serves the up-front dequantized copy — outputs must still match).

Runner/SamplingParams sections (PR 4): ``bench_sampling`` drains a
mixed greedy+sampled stream (one jitted program per decode tick) and
asserts sampled determinism across reruns plus greedy-row isolation;
``bench_basecaller`` streams simulated squiggle reads through the
BasecallerRunner and asserts the incremental CTC merge equals the
offline whole-read basecall, reporting reads/s and bases/s.

Decode-attention backend section (PR 5): ``bench_paged_attention``
drains the same workload through the fused Pallas paged-attention
kernel and the XLA gather reference, asserts token parity, and records
decode tok/s plus per-tick read-position accounting for both.

Mixed-traffic section (PR 6): ``bench_mixed_ticks`` replays bursty
Poisson arrivals through the unified co-batched scheduler and the
legacy split-tick one, asserts token parity between the modes, and
reports TTFT p50/p99 + decode-interval jitter p50/p99 for both.

Quantized-arena section (PR 7): ``bench_quantized`` serves the same
workload under bf16/fp8/int8 cache policies at equal slots, reports
honest total cache bytes (arena + scale leaves + pos + state), and
gates fused-vs-reference token parity over the int8 arena plus the
>= 1.8x byte-reduction floor for the best quantized policy.

Dispatch section (PR 10): ``bench_dispatch`` drains a 4x-oversubscribed
burst through the warmed async pipelined engine and the sync baseline,
gates token parity + ``retraces=0`` after warmup, reports tick-latency
p50/p99 per mode, and asserts the pipelined path clears a >= 1.15x
wall-clock throughput floor on CPU smoke.

Smoke mode (``run(emit)`` registry / CLI default) runs all four arch
families' smoke configs on CPU (quant variants on qwen only);
``--arch``/``--slots``/... scale it up on real hardware.
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_config
from repro.models import api
from repro.models.lm import transformer as tfm
from repro.serving.engine import Request, ServingEngine
from repro.serving.sampling import SamplingParams


def make_workload(cfg, slots: int, oversub: int, prompt_len: int,
                  max_tokens: int, seed: int = 0
                  ) -> List[Tuple[List[int], int]]:
    """(prompt, max_new) pairs; equal prompt lengths (static batching has
    no un-padded way to mix prompt lengths — that asymmetry is the point),
    output lengths spread wide so static batches straggle."""
    rs = np.random.RandomState(seed)
    n = slots * oversub
    out = []
    for _ in range(n):
        prompt = rs.randint(1, cfg.vocab_size, size=prompt_len).tolist()
        mnew = int(rs.randint(max(max_tokens // 8, 1), max_tokens + 1))
        out.append((prompt, mnew))
    return out


def make_static_fns(cfg, cache_len):
    """Jitted prefill + decode for the static path — built ONCE so warm
    and timed passes share compilations."""
    prefill = jax.jit(_prefill_fn(cfg, cache_len))
    step = jax.jit(lambda p, c, tok, t: tfm.decode_step(p, c, tok, t, cfg))
    return prefill, step


def run_static(params, cfg, workload, slots: int, fns
               ) -> Tuple[float, float, Dict[int, List[int]]]:
    """Static batching: groups of `slots`, lockstep decode to the longest.

    Returns (wall_s, decode_s, {request_index: tokens}). Tokens decoded
    past a request's max_new are discarded — that slot waste (a batch
    runs until its LONGEST member) is exactly the baseline's cost.
    """
    prefill, step = fns
    outputs: Dict[int, List[int]] = {}
    decode_s = 0.0
    t0 = time.perf_counter()
    for g0 in range(0, len(workload), slots):
        group = workload[g0:g0 + slots]
        P = len(group[0][0])
        toks = jnp.asarray([p for p, _ in group], jnp.int32)
        logits, caches = prefill(params, toks)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        outs = [[int(tok[i, 0])] for i in range(len(group))]
        horizon = max(m for _, m in group)
        d0 = time.perf_counter()
        for i in range(horizon - 1):
            logits, caches = step(params, caches, tok,
                                  jnp.asarray(P + i, jnp.int32))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            for b in range(len(group)):
                outs[b].append(int(tok[b, 0]))
        decode_s += time.perf_counter() - d0
        for b, (_, mnew) in enumerate(group):
            outputs[g0 + b] = outs[b][:mnew]
    return time.perf_counter() - t0, decode_s, outputs


def _prefill_fn(cfg, cache_len):
    def fn(p, tk):
        return tfm.prefill(p, tk, cfg, cache_len=cache_len,
                           cache_dtype=jnp.dtype(cfg.dtype))
    return fn


def run_engine(engine: ServingEngine, workload, eos_id: int = None
               ) -> Tuple[float, Dict[int, List[int]]]:
    """One full drain of the workload through an (already-built, possibly
    warm) engine. Metrics are reset so each pass reports itself."""
    engine.reset_stats()
    t0 = time.perf_counter()
    for i, (prompt, mnew) in enumerate(workload):
        engine.submit(Request(rid=i, prompt=prompt,
                              sampling=SamplingParams(max_new_tokens=mnew,
                                                      eos_id=eos_id)))
    done = engine.run()
    dt = time.perf_counter() - t0
    return dt, {i: r.out_tokens for i, r in done.items()}


def _truncate_eos(tokens: List[int], eos_id: int) -> List[int]:
    """Static-path outputs cut at the first EOS (inclusive) — what the
    engine emits when a request carries ``eos_id``."""
    if eos_id is None:
        return tokens
    out = []
    for t in tokens:
        out.append(t)
        if t == eos_id:
            break
    return out


def bench(emit, arch: str = "qwen1.5-4b-smoke", slots: int = 4,
          oversub: int = 2, prompt_len: int = 16, max_tokens: int = 24,
          prefill_chunk: int = 8, wbits_list=(0, 8, 4),
          eos_id: int = None, tag_arch: bool = False) -> None:
    cfg = get_config(arch)
    cache_len = prompt_len + max_tokens
    base_params = api.init_params(jax.random.key(0), cfg)
    workload = make_workload(cfg, slots, oversub, prompt_len, max_tokens)

    for wbits in wbits_list:
        if wbits:
            # BOTH paths serve the packed storage (dequant-on-read), so
            # the speedup isolates scheduling; packed-engine vs
            # dequantized-static token parity is tests/test_serving.py.
            from repro.launch.serve import quantize_for_serving
            eng_params = static_params = quantize_for_serving(base_params,
                                                              wbits)
        else:
            eng_params = static_params = base_params
        tag = f"int{wbits}" if wbits else "fp32"
        if tag_arch:
            tag = arch.replace("-smoke", "").replace("-", "_") + "_" + tag

        # build both paths' programs once; warm pass compiles, timed
        # pass measures steady state
        static_fns = make_static_fns(cfg, cache_len)
        engine = ServingEngine(eng_params, cfg, n_slots=slots,
                               cache_len=cache_len,
                               prefill_chunk=prefill_chunk,
                               cache_dtype=jnp.dtype(cfg.dtype))
        run_static(static_params, cfg, workload, slots, static_fns)
        run_engine(engine, workload, eos_id)
        # best-of-3 timed passes: per-step device time is sub-ms at smoke
        # scale, so single passes are hostage to scheduler jitter
        runs_s = [run_static(static_params, cfg, workload, slots,
                             static_fns) for _ in range(3)]
        dt_s = min(r[0] for r in runs_s)
        dec_s = min(r[1] for r in runs_s)
        out_s = {i: _truncate_eos(t, eos_id)
                 for i, t in runs_s[0][2].items()}
        useful = sum(len(t) for t in out_s.values())
        runs_e = []
        for _ in range(3):
            dt, out_e = run_engine(engine, workload, eos_id)
            runs_e.append((dt, engine.metrics))
        dt_e = min(r[0] for r in runs_e)
        engine_metrics = max((m for _, m in runs_e),
                             key=lambda m: m.summary()["decode_tokens_per_s"])

        parity = all(out_e[i] == out_s[i] for i in range(len(workload)))
        # Steady-state decode throughput: USEFUL tokens per second spent
        # in decode steps. The static baseline spends decode time on
        # already-finished slots (padding); the engine refills them. This
        # is the apples-to-apples metric — it cancels per-dispatch
        # overhead, compile noise and prefill cost, which at smoke scale
        # otherwise dominate wall-clock.
        useful_decode = useful - len(workload)   # token #1 is prefill's
        dtps_s = useful_decode / max(dec_s, 1e-9)
        m = engine_metrics.summary()
        dtps_e = m["decode_tokens_per_s"]
        tps_s, tps_e = useful / dt_s, useful / dt_e
        emit(f"serving_static_{tag}", dec_s / useful_decode * 1e6,
             f"decode={dtps_s:.1f}tok/s;wall={tps_s:.1f}tok/s")
        emit(f"serving_engine_{tag}",
             engine_metrics.decode_time * 1e6
             / max(engine_metrics.decode_tokens, 1),
             f"decode={dtps_e:.1f}tok/s;speedup={dtps_e/dtps_s:.2f}x;"
             f"wall={tps_e:.1f}tok/s;"
             f"parity={'ok' if parity else 'MISMATCH'};"
             f"occupancy={m['slot_occupancy']:.2f}/{slots}")
        if not parity:
            # MoE token-choice capacity routing is batch-composition
            # dependent (engine slot mix != static groups — see the
            # ServingEngine docstring / tests/test_decode.py), so at
            # large slot counts MoE divergence is expected behavior:
            # report it instead of aborting the benchmark. Non-MoE
            # archs must match exactly.
            if cfg.n_experts:
                emit(f"serving_engine_{tag}__MOE_PARITY_DIVERGENCE", 0.0,
                     "token-choice capacity routing is composition-"
                     "dependent; see ServingEngine docstring")
            else:
                raise AssertionError(f"{tag}: engine/static token mismatch")
        if dtps_e <= dtps_s:
            emit(f"serving_engine_{tag}__SLOWER", 0.0,
                 f"{dtps_e:.1f}<={dtps_s:.1f}")


def bench_paged(emit, arch: str = "qwen1.5-4b-smoke", base_slots: int = 2,
                cache_len: int = 40, block_len: int = 8,
                prefill_chunk: int = 8, seed: int = 0) -> None:
    """Paged pool vs the contiguous layout at EQUAL KV arena bytes.

    The contiguous baseline is the degenerate paged config (one
    ``cache_len``-sized block per slot): ``base_slots`` slots, each
    reserving worst-case capacity up front, so only ``base_slots``
    requests ever run concurrently. The paged engine holds the same KV
    position budget (``base_slots * cache_len``) in ``block_len`` blocks
    but exposes ``2 * base_slots`` decode slots: a mixed short/long
    workload (mostly short requests + a few worst-case ones) admits at
    roughly double the concurrency because short requests only occupy
    the blocks they touch. The contiguous pool must queue the same
    workload behind its fully-reserved slots. Emits concurrency, queue
    depth, pool utilization and useful decode throughput for both, and
    checks the paged outputs token-identical to the contiguous ones
    (both engines are greedy over the same weights). The arena (KV
    bytes) budget is equal by construction; per-slot int32 position
    words and any SSM state scale with the doubled slot count —
    ``cache_kib``/``cache_bytes_ratio`` in the output keep that
    honest. Pure-SSM archs have no KV to page and are skipped.
    """
    # exact equal-arena accounting needs cache_len | block_len: round
    # down so the paged budget never silently undercuts the baseline
    cache_len = max(cache_len // block_len, 1) * block_len
    cfg = get_config(arch)
    if not tfm.paged_group_layout(cfg, cache_len, block_len):
        # pure-SSM archs have no KV to page: a "paged" engine is just
        # more slots of per-slot state, so the equal-bytes comparison
        # would measure slot count, not paging — skip honestly
        emit("serving_paged_vs_contig__SKIPPED", 0.0,
             f"{arch} has no KV-bearing groups (nothing to page)")
        return
    params = api.init_params(jax.random.key(0), cfg)
    rs = np.random.RandomState(seed)
    # mixed workload: 3/4 one-block short requests, 1/4 worst-case longs
    # (the long ones EXACTLY fill cache_len — the boundary the admission
    # off-by-one fix admits: P + max_new - 1 == cache_len)
    workload = []
    for i in range(base_slots * 6):
        if i % 4 == 3:
            plen, mnew = cache_len // 2, cache_len // 2 + 1   # exact fit
        else:
            plen = max(block_len // 2, 1)
            mnew = block_len - plen + 1       # writes exactly one block
        prompt = rs.randint(1, cfg.vocab_size, size=plen).tolist()
        workload.append((prompt, mnew))

    budget_blocks = base_slots * (cache_len // block_len)
    variants = {
        "contig": dict(n_slots=base_slots, block_len=cache_len,
                       n_blocks=base_slots),
        "paged": dict(n_slots=2 * base_slots, block_len=block_len,
                      n_blocks=budget_blocks),
    }
    outs, stats = {}, {}
    for name, kw in variants.items():
        engine = ServingEngine(params, cfg, cache_len=cache_len,
                               prefill_chunk=prefill_chunk,
                               cache_dtype=jnp.dtype(cfg.dtype), **kw)
        run_engine(engine, workload)                 # warm/compile
        _, out = run_engine(engine, workload)
        outs[name] = out
        m = engine.metrics.summary()
        stats[name] = (m, engine.pool.nbytes())
        emit(f"serving_{name}_pool_{arch.replace('-smoke', '').replace('-', '_')}",
             engine.metrics.decode_time * 1e6
             / max(engine.metrics.decode_tokens, 1),
             f"decode={m['decode_tokens_per_s']:.1f}tok/s;"
             f"concurrency={m['slot_occupancy']:.2f}/{kw['n_slots']};"
             f"queue_max={m['queue_depth_max']:.0f};"
             f"pool_util_max={m['pool_util_max']:.2f};"
             f"preempts={m['preemptions']:.0f};"
             f"kv_positions={kw['n_blocks'] * kw['block_len']};"
             f"cache_kib={engine.pool.nbytes() / 1024:.0f}")
    parity = all(outs["paged"][i] == outs["contig"][i]
                 for i in range(len(workload)))
    mp, mc = stats["paged"][0], stats["contig"][0]
    gain = (mp["slot_occupancy"] / max(mc["slot_occupancy"], 1e-9))
    emit("serving_paged_vs_contig", 0.0,
         f"concurrency_gain={gain:.2f}x;"
         f"queue_max_contig={mc['queue_depth_max']:.0f};"
         f"queue_max_paged={mp['queue_depth_max']:.0f};"
         f"cache_bytes_ratio={stats['paged'][1] / stats['contig'][1]:.2f};"
         f"parity={'ok' if parity else 'MISMATCH'}")
    if not parity and not cfg.n_experts:
        raise AssertionError("paged/contiguous token mismatch")
    if mp["slot_occupancy"] <= mc["slot_occupancy"]:
        emit("serving_paged_vs_contig__NO_GAIN", 0.0,
             f"{mp['slot_occupancy']:.2f}<={mc['slot_occupancy']:.2f}")


def bench_sampling(emit, arch: str = "qwen1.5-4b-smoke", slots: int = 2,
                   oversub: int = 2, prompt_len: int = 8,
                   max_tokens: int = 12, prefill_chunk: int = 4,
                   seed: int = 0) -> None:
    """Sampled decode through the engine: a mixed greedy+sampled stream
    (every decode batch carries both kinds of rows — one jitted
    program). Checks (a) DETERMINISM — two full drains produce
    token-identical outputs, sampled rows included, because sample
    noise is keyed by (seed, rid, step); (b) ISOLATION — the greedy
    requests' tokens are identical to an all-greedy run of the same
    engine (a hot-temperature neighbour must not perturb a greedy
    row). Emits decode throughput for the mixed run."""
    cfg = get_config(arch)
    cache_len = prompt_len + max_tokens
    params = api.init_params(jax.random.key(0), cfg)
    base = make_workload(cfg, slots, oversub, prompt_len, max_tokens, seed)
    engine = ServingEngine(params, cfg, n_slots=slots, cache_len=cache_len,
                           prefill_chunk=prefill_chunk,
                           cache_dtype=jnp.dtype(cfg.dtype))

    def drain(sampled: bool):
        engine.reset_stats()
        t0 = time.perf_counter()
        for i, (prompt, mnew) in enumerate(base):
            sp = SamplingParams(max_new_tokens=mnew, temperature=0.8,
                                top_k=20, top_p=0.95, seed=100 + i) \
                if sampled and i % 2 else SamplingParams(max_new_tokens=mnew)
            engine.submit(Request(rid=i, prompt=prompt, sampling=sp))
        done = engine.run()
        return time.perf_counter() - t0, {i: r.out_tokens
                                          for i, r in done.items()}

    drain(True)                                   # warm/compile
    dt1, out1 = drain(True)
    _, out2 = drain(True)
    _, greedy = drain(False)
    determinism = out1 == out2
    isolation = all(out1[i] == greedy[i] for i in range(0, len(base), 2))
    m = engine.metrics.summary()
    n_sampled = len(base) // 2
    emit("serving_sampled_mixed",
         engine.metrics.decode_time * 1e6
         / max(engine.metrics.decode_tokens, 1),
         f"decode={m['decode_tokens_per_s']:.1f}tok/s;"
         f"mix={len(base)-n_sampled}greedy+{n_sampled}sampled;"
         f"determinism={'ok' if determinism else 'MISMATCH'};"
         f"greedy_isolation={'ok' if isolation else 'MISMATCH'}")
    if not determinism:
        raise AssertionError("sampled decode not deterministic across "
                             "reruns (seed/rid/step keying broke)")
    if not isolation:
        raise AssertionError("greedy rows perturbed by sampled neighbours")


def bench_basecaller(emit, arch: str = "bonito-smoke", slots: int = 2,
                     reads: int = 6, read_bases: int = 80,
                     chunk_samples: int = 256, seed: int = 0) -> None:
    """Squiggle serving through the BasecallerRunner: simulated reads
    stream as halo-padded chunks with incremental greedy CTC merge.
    Emits reads/s + bases/s and checks every served read's base calls
    EQUAL the offline whole-read forward + greedy_decode (bit-exact
    for non-act-quantized configs — the CTC-merge parity gate)."""
    from repro.data.squiggle import (SquiggleConfig, normalize, pore_table,
                                     simulate_read)
    from repro.models.basecaller import model as bc
    from repro.models.basecaller.ctc import greedy_decode
    cfg = get_config(arch)
    params = api.init_params(jax.random.key(0), cfg)
    state = bc.init_state(cfg)
    rs = np.random.RandomState(seed)
    sim = SquiggleConfig(noise=0.1, drift=0.0)
    table = pore_table()
    sigs = []
    for i in range(reads):
        n = int(rs.randint(max(read_bases // 2, 8), read_bases + 1))
        sig, _ = simulate_read(rs, sim, table, n)
        sigs.append(normalize(sig))
    engine = ServingEngine(params, cfg, n_slots=slots,
                           chunk_samples=chunk_samples)

    def drain():
        engine.reset_stats()
        t0 = time.perf_counter()
        for i, s in enumerate(sigs):
            engine.submit(Request(rid=i, signal=s))
        done = engine.run()
        return time.perf_counter() - t0, done

    drain()                                       # warm/compile
    dt, done = drain()
    offline = jax.jit(lambda p, x: bc.forward(p, state, x, cfg,
                                              train=False)[0])
    parity = True
    n_bases = 0
    for i, s in enumerate(sigs):
        ref = np.asarray(offline(params, jnp.asarray(s[None, :, None])))
        want = [int(v) for v in greedy_decode(ref)[0]]
        n_bases += len(want)
        parity &= done[i].out_tokens == want
    m = engine.metrics.summary()
    emit(f"serving_basecaller_{arch.replace('-smoke', '').replace('-', '_')}",
         dt / reads * 1e6,
         f"reads_per_s={reads/max(dt,1e-9):.2f};"
         f"bases_per_s={n_bases/max(dt,1e-9):.0f};"
         f"chunk={engine.runner.core};halo={engine.runner.halo};"
         f"occupancy={m['slot_occupancy']:.2f}/{slots};"
         f"ctc_merge_parity={'ok' if parity else 'MISMATCH'}")
    if not parity:
        raise AssertionError(f"{arch}: served base calls != offline "
                             f"whole-read basecall")


def bench_read_until(emit, arch: str = "bonito-smoke", slots: int = 2,
                     reads: int = 6, read_bases: int = 150,
                     chunk_samples: int = 300, eject_after_chunks: int = 2,
                     off_target_frac: float = 0.5, seed: int = 0) -> None:
    """Streaming + read-until gate: every read streams in as appended
    chunks (StreamingRequest) with the trained start-of-read classifier
    armed. Hard gates: (a) on-target reads' streamed tokens EQUAL the
    whole-read engine run (token parity through the live-append path);
    (b) every off-target (white-noise) read is ejected, no on-target
    read is, and each ejection consumes at most ``eject_after_chunks``
    windows of basecall compute; (c) ejected reads' partial bases are a
    PREFIX of their would-be full basecall, and samples saved > 0."""
    from repro.data.squiggle import (SquiggleConfig, normalize, pore_table,
                                     simulate_read)
    from repro.models.basecaller import classifier as rc
    from repro.serving.stream import ReadUntil, StreamingRequest
    cfg = get_config(arch)
    params = api.init_params(jax.random.key(0), cfg)
    rs = np.random.RandomState(seed)
    sim = SquiggleConfig(noise=0.1, drift=0.0)
    table = pore_table()
    n_off = max(int(round(reads * off_target_frac)), 1)
    sigs, is_off = [], []
    for i in range(reads):
        n = int(rs.randint(max(read_bases // 2, 8), read_bases + 1))
        off = i < n_off
        if off:
            sigs.append(normalize(rs.randn(n * 9).astype(np.float32)))
        else:
            sig, _ = simulate_read(rs, sim, table, n)
            sigs.append(normalize(sig))
        is_off.append(off)

    # whole-read reference run (no read-until) — also yields the
    # would-be full basecall of every off-target read for the prefix gate
    ref = ServingEngine(params, cfg, n_slots=slots,
                        chunk_samples=chunk_samples)
    for i, s in enumerate(sigs):
        ref.submit(Request(rid=i, signal=s))
    full = ref.run()

    probe = ref.runner          # geometry for classifier training windows
    window = probe.core + 2 * probe.halo
    x, y = rc.make_training_set(np.random.RandomState(seed + 77), window,
                                n_per_class=24)
    cls_params, _ = rc.fit(rc.init_params(jax.random.key(seed + 1)), x, y,
                           steps=120, lr=0.1)
    engine = ServingEngine(
        params, cfg, n_slots=slots, chunk_samples=chunk_samples,
        read_until=ReadUntil(params=cls_params,
                             eject_after_chunks=eject_after_chunks))

    def drain(append: int = 512):
        engine.reset_stats()
        live = {}
        t0 = time.perf_counter()
        for i, s in enumerate(sigs):
            req = StreamingRequest(rid=i)
            engine.submit(req)
            live[i] = [req, s, 0]
        while live:
            for rid in list(live):
                req, s, ptr = live[rid]
                if req.done:
                    if req.ejected and ptr < s.shape[0]:
                        engine.metrics.record_samples_saved(
                            s.shape[0] - ptr)
                    del live[rid]
                    continue
                nxt = min(ptr + append, s.shape[0])
                if nxt > ptr:
                    req.append(s[ptr:nxt])
                    live[rid][2] = nxt
                elif not req.stream_finished:
                    req.finish()
            if engine.busy:
                engine.step()
        while engine.busy:
            engine.step()
        return time.perf_counter() - t0, engine.drain_completed()

    drain()                                       # warm/compile
    dt, done = drain()
    m = engine.metrics.summary()
    ejected = {i for i, r in done.items() if r.ejected}
    parity = all(done[i].out_tokens == full[i].out_tokens
                 for i in range(reads) if i not in ejected)
    prefix_ok = all(
        done[i].out_tokens == full[i].out_tokens[:len(done[i].out_tokens)]
        for i in ejected)
    per_eject = (m["ejected_consumed_samples"] / len(ejected)
                 if ejected else 0.0)
    emit(f"serving_read_until_{arch.replace('-smoke', '').replace('-', '_')}",
         dt / reads * 1e6,
         f"ejections={len(ejected)};off_target={n_off};"
         f"samples_saved={m['samples_saved']:.0f};"
         f"consumed_per_eject={per_eject:.0f};"
         f"eject_budget={eject_after_chunks * engine.runner.core};"
         f"token_parity={'ok' if parity else 'MISMATCH'};"
         f"eject_prefix={'ok' if prefix_ok else 'MISMATCH'}")
    if not parity:
        raise AssertionError(f"{arch}: streamed on-target base calls != "
                             f"whole-read engine basecall")
    if not prefix_ok:
        raise AssertionError(f"{arch}: ejected reads' partial bases are "
                             f"not a prefix of their full basecall")
    if ejected != {i for i in range(reads) if is_off[i]}:
        raise AssertionError(
            f"{arch}: read-until ejected {sorted(ejected)}, expected "
            f"exactly the off-target reads "
            f"{[i for i in range(reads) if is_off[i]]}")
    if per_eject > eject_after_chunks * engine.runner.core:
        raise AssertionError(
            f"{arch}: ejections consumed {per_eject:.0f} samples each — "
            f"more than {eject_after_chunks} chunks of "
            f"{engine.runner.core}")
    if m["samples_saved"] <= 0:
        raise AssertionError(f"{arch}: read-until saved no samples")


def bench_paged_attention(emit, arch: str = "qwen1.5-4b-smoke",
                          slots: int = 2, oversub: int = 2,
                          prompt_len: int = 8, max_tokens: int = 12,
                          prefill_chunk: int = 4, block_len: int = 4,
                          seed: int = 0) -> None:
    """Decode-attention backend comparison: the fused Pallas
    paged-attention kernel (reading straight from the block arena) vs
    the XLA gather reference, through the full engine on the same
    workload. Asserts fused-vs-reference TOKEN PARITY (greedy decode
    must be identical) and records decode tok/s for both backends plus
    the bytes-moved story: the reference materialises the (B,
    T*block_len) logical KV view per layer per tick, the fused path
    reads only assigned blocks. On CPU the fused kernel runs in Pallas
    interpret mode — a correctness gate, not a speed contest (interpret
    is orders of magnitude slower; the tok/s numbers are still emitted
    so TPU runs of the same section read apples-to-apples)."""
    cfg = get_config(arch)
    cache_len = prompt_len + max_tokens
    params = api.init_params(jax.random.key(0), cfg)
    workload = make_workload(cfg, slots, oversub, prompt_len, max_tokens,
                             seed)
    outs, stats = {}, {}
    for backend in ("xla", "pallas"):
        engine = ServingEngine(params, cfg, n_slots=slots,
                               cache_len=cache_len,
                               prefill_chunk=prefill_chunk,
                               cache_dtype=jnp.float32,
                               block_len=block_len, attn_backend=backend)
        run_engine(engine, workload)                   # warm/compile
        dt, out = run_engine(engine, workload)
        outs[backend] = out
        m = engine.metrics.summary()
        pool = engine.pool
        # per-tick read accounting (positions, per layer group): the
        # gather path always touches the full logical view; the fused
        # path touches only blocks the live slots actually own
        leff = {g: T * pool.block_len for g, T in pool.layout.items()}
        gather_pos = sum(slots * L for L in leff.values())
        fused_pos = sum(m["pool_util_mean"] * nb * pool.block_len
                        for g, nb in pool.n_blocks.items())
        stats[backend] = m
        emit(f"serving_attn_{backend}",
             engine.metrics.decode_time * 1e6
             / max(engine.metrics.decode_tokens, 1),
             f"decode={m['decode_tokens_per_s']:.1f}tok/s;"
             f"wall={sum(len(t) for t in out.values())/max(dt, 1e-9):.1f}tok/s;"
             f"read_positions_per_tick="
             f"{fused_pos if backend == 'pallas' else gather_pos:.0f};"
             f"backend={engine.runner.attn_backend}"
             + (";interpret" if backend == "pallas" else ""))
    parity = all(outs["pallas"][i] == outs["xla"][i]
                 for i in range(len(workload)))
    mx, mp = stats["xla"], stats["pallas"]
    emit("serving_attn_backend_parity", 0.0,
         f"parity={'ok' if parity else 'MISMATCH'};"
         f"decode_xla={mx['decode_tokens_per_s']:.1f}tok/s;"
         f"decode_pallas={mp['decode_tokens_per_s']:.1f}tok/s")
    if not parity:
        raise AssertionError(
            "fused (pallas) vs reference (xla) decode token mismatch")


def bench_quantized(emit, arch: str = "qwen1.5-4b-smoke", slots: int = 2,
                    oversub: int = 2, prompt_len: int = 8,
                    max_tokens: int = 12, prefill_chunk: int = 4,
                    block_len: int = 8, seed: int = 0) -> None:
    """Quantized KV arena at EQUAL SLOTS (PR 7): serve the same greedy
    workload under bf16 / fp8 / int8 cache policies and report honest
    total cache bytes (``CachePool.nbytes_by_class`` — arena + scale
    leaves + pos + SSM state), decode tok/s, and token parity vs the
    bf16 row. Gates:

    - fused-vs-reference token parity over the QUANTIZED arena (the
      int8 scale leaves ride the Pallas kernels as extra operands and
      the XLA gather dequantizes identically) — hard assert;
    - best quantized policy's total-cache-bytes reduction >= 1.8x vs
      bf16 (deterministic shape math, not timing; skipped with a marker
      when the platform lacks fp8 AND head_dim is too small for int8's
      scale overhead to amortize) — hard assert;
    - decode tok/s no worse than bf16 — emitted as a ``__SLOWER``
      marker (CPU timing jitters; TPU runs read the same section).

    int8 token drift vs bf16 is REPORTED, not asserted: a quantized
    cache is a numerics change, unlike the backend comparison."""
    from repro.serving.cache import fp8_supported
    cfg = get_config(arch)
    cache_len = prompt_len + max_tokens
    params = api.init_params(jax.random.key(0), cfg)
    workload = make_workload(cfg, slots, oversub, prompt_len, max_tokens,
                             seed)

    def build(policy, backend="xla"):
        return ServingEngine(params, cfg, n_slots=slots,
                             cache_len=cache_len,
                             prefill_chunk=prefill_chunk,
                             cache_dtype=jnp.dtype(cfg.dtype),
                             quant_policy=policy, block_len=block_len,
                             attn_backend=backend)

    rows = {}
    for mode in ("bf16", "fp8", "int8"):
        engine = build(mode)
        run_engine(engine, workload)                     # warm/compile
        best_tps, out = 0.0, None
        for _ in range(3):
            _, out = run_engine(engine, workload)
            best_tps = max(best_tps,
                           engine.metrics.summary()["decode_tokens_per_s"])
        pool = engine.pool
        rows[mode] = (best_tps, pool.nbytes(), pool.nbytes_by_class(),
                      out, pool.quant_policy.describe())
    base_tps, base_bytes, base_by, base_out, _ = rows["bf16"]
    for mode in ("bf16", "fp8", "int8"):
        tps, total, by, out, resolved = rows[mode]
        parity = out == base_out
        emit(f"serving_quant_{mode}", total,
             f"decode={tps:.1f}tok/s;cache_bytes={total};"
             f"arena={by['arena']};scales={by['scales']};"
             f"pos={by['pos']};state={by['state']};"
             f"vs_bf16={base_bytes/max(total,1):.2f}x;"
             f"resolved={resolved};"
             f"tokens_vs_bf16={'ok' if parity else 'drift'}")
        if mode != "bf16" and tps < base_tps:
            emit(f"serving_quant_{mode}__SLOWER", 0.0,
                 f"{tps:.1f}<{base_tps:.1f}tok/s")

    # fused-vs-reference parity over the int8 arena: scales must reach
    # the kernel and dequantize identically to the gather reference
    eng_p = build("int8", "pallas")
    run_engine(eng_p, workload)
    _, out_p = run_engine(eng_p, workload)
    fused_parity = out_p == rows["int8"][3]
    emit("serving_quant_attn_backend_parity", 0.0,
         f"parity={'ok' if fused_parity else 'MISMATCH'};policy=int8")
    if not fused_parity:
        raise AssertionError(
            "int8 arena: fused (pallas) vs reference (xla) decode "
            "token mismatch — scale leaves diverge between backends")

    best_ratio = max(base_bytes / max(rows[m][1], 1)
                     for m in ("fp8", "int8"))
    if not fp8_supported() and best_ratio < 1.8:
        emit("serving_quant_ratio__SKIPPED", best_ratio,
             "no fp8 on this platform and int8 scale overhead dominates "
             "at smoke head_dim")
    else:
        emit("serving_quant_ratio", best_ratio,
             f"best_vs_bf16={best_ratio:.2f}x;floor=1.8x")
        if best_ratio < 1.8:
            raise AssertionError(
                f"quantized cache only {best_ratio:.2f}x smaller than "
                f"bf16 (floor 1.8x at equal slots)")


def bench_mixed_ticks(emit, arch: str = "qwen1.5-4b-smoke", slots: int = 4,
                      prompt_len: int = 24, max_tokens: int = 20,
                      prefill_chunk: int = 4, max_prefill_tokens: int = 8,
                      mean_gap: float = 2.0, seed: int = 0) -> None:
    """Mixed-traffic scheduling (PR 6): the unified co-batched tick vs
    the legacy split-tick schedule on IDENTICAL bursty Poisson traffic.

    Arrival gaps (in scheduler ticks) are Poisson-drawn, so admissions
    land mid-decode and every new request's chunked prefill competes
    with running decodes — exactly the case split-tick scheduling
    handles badly (each prefill chunk is its own runner dispatch, so an
    admission stalls every running decode for the whole chunk walk,
    spiking decode-interval jitter and queue-time TTFT). The co-batched
    engine folds the same chunks into the decode program under a
    ``max_prefill_tokens`` budget. Asserts TOKEN PARITY between the two
    modes (mixed ticks are a scheduling change only — the acceptance
    gate) and reports TTFT p50/p99 + decode-interval jitter p50/p99 for
    both; regressions emit a ``__NO_GAIN`` marker rather than aborting
    (wall-clock at smoke scale is scheduler-jitter-prone on CPU)."""
    cfg = get_config(arch)
    cache_len = prompt_len + max_tokens
    params = api.init_params(jax.random.key(0), cfg)
    rs = np.random.RandomState(seed)
    n = slots * 3
    reqs = [(rs.randint(1, cfg.vocab_size, size=prompt_len).tolist(),
             int(rs.randint(max(max_tokens // 2, 1), max_tokens + 1)))
            for _ in range(n)]
    arrive = np.cumsum(rs.poisson(mean_gap, size=n))
    arrive -= arrive[0]                     # the first request opens play

    def drain(co_batch: bool):
        engine = ServingEngine(params, cfg, n_slots=slots,
                               cache_len=cache_len,
                               prefill_chunk=prefill_chunk,
                               cache_dtype=jnp.dtype(cfg.dtype),
                               co_batch=co_batch,
                               max_prefill_tokens=(max_prefill_tokens
                                                   if co_batch else 0))

        def one_pass():
            engine.reset_stats()
            i, tick = 0, 0
            t0 = time.perf_counter()
            while i < n or engine.busy:
                while i < n and arrive[i] <= tick:
                    p, m = reqs[i]
                    engine.submit(Request(
                        rid=i, prompt=list(p),
                        sampling=SamplingParams(max_new_tokens=m)))
                    i += 1
                engine.step()
                tick += 1
            return (time.perf_counter() - t0,
                    {r: engine.completed[r].out_tokens
                     for r in engine.completed})

        one_pass()                          # warm/compile
        dt, out = one_pass()
        return dt, out, engine.metrics.summary()

    dt_c, out_c, mc = drain(True)
    dt_s, out_s, ms = drain(False)
    parity = out_c == out_s
    for name, m, dt in (("cobatch", mc, dt_c), ("split", ms, dt_s)):
        emit(f"serving_mixed_{name}", m["ttft_p99_s"] * 1e6,
             f"ttft_p50={m['ttft_p50_s']*1e3:.1f}ms;"
             f"ttft_p99={m['ttft_p99_s']*1e3:.1f}ms;"
             f"decode_jitter_p50={m['decode_interval_p50_s']*1e3:.2f}ms;"
             f"decode_jitter_p99={m['decode_interval_p99_s']*1e3:.2f}ms;"
             f"decode={m['decode_tokens_per_s']:.1f}tok/s;"
             f"wall={dt:.2f}s")
    emit("serving_mixed_vs_split", 0.0,
         f"parity={'ok' if parity else 'MISMATCH'};"
         f"ttft_p99_ratio="
         f"{ms['ttft_p99_s'] / max(mc['ttft_p99_s'], 1e-9):.2f}x;"
         f"jitter_p99_ratio="
         f"{ms['decode_interval_p99_s'] / max(mc['decode_interval_p99_s'], 1e-9):.2f}x;"
         f"prefill_budget={max_prefill_tokens}tok")
    if not parity:
        raise AssertionError(
            "co-batched vs split-tick token mismatch — unified mixed "
            "ticks must be a scheduling change only")
    if mc["ttft_p99_s"] >= ms["ttft_p99_s"]:
        emit("serving_mixed_vs_split__NO_TTFT_GAIN", 0.0,
             f"{mc['ttft_p99_s']*1e3:.1f}>={ms['ttft_p99_s']*1e3:.1f}ms")
    if mc["decode_interval_p99_s"] >= ms["decode_interval_p99_s"]:
        emit("serving_mixed_vs_split__NO_JITTER_GAIN", 0.0,
             f"{mc['decode_interval_p99_s']*1e3:.2f}>="
             f"{ms['decode_interval_p99_s']*1e3:.2f}ms")


def bench_dispatch(emit, arch: str = "qwen1.5-4b-smoke", slots: int = 4,
                   oversub: int = 4, prompt_len: int = 12,
                   max_tokens: int = 32, prefill_chunk: int = 4,
                   floor: float = 1.15, seed: int = 0) -> None:
    """Async pipelined dispatch (PR 10) vs the sync engine under a 4x
    oversubscribed burst: every request is submitted up front, so the
    drain is back-to-back full-width ticks — the regime where hiding
    the per-tick token readback behind the next tick's dispatch pays.
    Both engines are warmed (``engine.warmup()``) with mid-traffic plan
    compiles a HARD ERROR (``require_warm``), so the comparison times
    steady-state dispatch only. Gates: token parity between the modes
    (the one-tick readback lag must be a latency change only — hard
    assert), ``retraces=0`` after warmup (hard assert), and a
    ``>= floor``x wall-clock useful-token throughput for the pipelined
    path (hard assert — the floor is calibrated for CPU smoke, where
    python scheduling is a large tick fraction and overlapping it with
    XLA's async compute threads is exactly the win being measured).

    The throughput floor applies only on hosts with >= 2 CPU cores:
    pipelining overlaps host work with device compute, and on a
    single-core host the two time-slice the SAME core — the overlap is
    physically impossible, so the floor is provably unreachable there.
    Single-core hosts emit an explicit ``__FLOOR_SKIPPED`` marker (no
    silent pass) and still hard-assert a ``>= 0.6``x sanity bound so a
    catastrophic async regression cannot hide behind the skip.
    Tick-latency p50/p99 and bucket hit counts are reported per mode."""
    cfg = get_config(arch)
    cache_len = prompt_len + max_tokens
    params = api.init_params(jax.random.key(0), cfg)
    workload = make_workload(cfg, slots, oversub, prompt_len, max_tokens,
                             seed)

    outs, tput, ticks = {}, {}, {}
    for name, async_ in (("sync", False), ("async", True)):
        engine = ServingEngine(params, cfg, n_slots=slots,
                               cache_len=cache_len,
                               prefill_chunk=prefill_chunk,
                               cache_dtype=jnp.dtype(cfg.dtype),
                               async_dispatch=async_)
        engine.warmup()
        engine.runner.plans.require_warm = True
        run_engine(engine, workload)                 # scheduling warm pass
        best = None
        for _ in range(3):
            dt, out = run_engine(engine, workload)
            m = engine.metrics.summary()
            if best is None or dt < best[0]:
                best = (dt, out, m)
        dt, out, m = best
        outs[name] = out
        useful = sum(len(t) for t in out.values())
        tput[name] = useful / max(dt, 1e-9)
        ticks[name] = m
        emit(f"serving_dispatch_{name}", dt * 1e6,
             f"wall={tput[name]:.1f}tok/s;"
             f"tick_p50={m['tick_latency_p50_s']*1e3:.2f}ms;"
             f"tick_p99={m['tick_latency_p99_s']*1e3:.2f}ms;"
             f"bucket_hits={m['bucket_hits']:.0f};"
             f"plans_warmed={m['plans_warmed']:.0f};"
             f"retraces={m['retraces']:.0f}")
        if m["retraces"]:
            raise AssertionError(
                f"{name} engine retraced {m['retraces']:.0f} plan(s) "
                f"after warmup — the bucket set is not closed over the "
                f"schedulable tick shapes")
    parity = outs["async"] == outs["sync"]
    speedup = tput["async"] / max(tput["sync"], 1e-9)
    emit("serving_dispatch_async_vs_sync", 0.0,
         f"parity={'ok' if parity else 'MISMATCH'};"
         f"speedup={speedup:.2f}x;floor={floor:.2f}x;"
         f"tick_p99_sync={ticks['sync']['tick_latency_p99_s']*1e3:.2f}ms;"
         f"tick_p99_async={ticks['async']['tick_latency_p99_s']*1e3:.2f}ms")
    if not parity:
        raise AssertionError(
            "async pipelined vs sync token mismatch — the one-tick "
            "readback lag must not change any request's tokens")
    if (os.cpu_count() or 1) < 2:
        # single core: host scheduling and XLA compute time-slice the
        # same core, so there is nothing to overlap onto — the floor
        # is unreachable by construction, not by regression
        emit("serving_dispatch_async_vs_sync__FLOOR_SKIPPED", 0.0,
             f"reason=single-core-host;speedup={speedup:.2f}x;"
             f"sanity_floor=0.60x")
        if speedup < 0.6:
            raise AssertionError(
                f"pipelined dispatch {speedup:.2f}x the sync engine on "
                f"a single-core host — even with zero overlap available "
                f"the pipeline overhead must stay bounded (>= 0.6x)")
    elif speedup < floor:
        raise AssertionError(
            f"pipelined dispatch only {speedup:.2f}x the sync engine "
            f"(floor {floor:.2f}x) — the deferred readback is not "
            f"hiding host scheduling behind device compute")


# One smoke config per slot-servable cache family. Quant variants run on
# qwen only — wbits isolates scheduling, not the arch's cache layout.
FAMILY_ARCHS = ("qwen1.5-4b-smoke", "mamba2-130m-smoke",
                "hymba-1.5b-smoke", "deepseek-v3-671b-smoke")


def run(emit) -> None:
    """benchmarks.run registry entry point (smoke scale)."""
    for arch in FAMILY_ARCHS:
        wbits = (0, 8, 4) if arch.startswith("qwen") else (0,)
        bench(emit, arch=arch, wbits_list=wbits, tag_arch=True)
    bench_paged(emit)
    bench_paged_attention(emit)
    bench_quantized(emit, slots=4, prompt_len=16, max_tokens=24)
    bench_mixed_ticks(emit, slots=4, prompt_len=32, max_tokens=24,
                      prefill_chunk=4, max_prefill_tokens=8)
    bench_sampling(emit, slots=4, oversub=2, prompt_len=16, max_tokens=24,
                   prefill_chunk=8)
    bench_basecaller(emit, reads=8, read_bases=120)
    bench_read_until(emit, reads=8)
    bench_dispatch(emit)


def run_smoke(emit) -> None:
    """Fast CI gate: engine-vs-static token parity through the paged
    pool on the dense smoke arch, the paged-vs-contiguous admission
    comparison, a fused-vs-reference decode-attention backend section
    (token parity + decode tok/s for both backends, the Pallas kernel
    in interpret mode on CPU), a mixed-traffic scheduling section
    (co-batched vs split-tick token parity + TTFT/decode-jitter
    percentiles under Poisson arrivals), a mixed greedy+sampled decode section
    (determinism + greedy isolation), a quantized-arena section
    (bf16/fp8/int8 cache bytes + tok/s, int8 fused-vs-reference token
    parity, the 1.8x byte floor), a basecaller-runner section
    (reads/s + CTC-merge parity vs the offline whole-read basecall),
    and a read-until section (streamed-vs-whole-read token parity
    through live appends + classifier-driven ejection of off-target
    reads within the chunk budget, with samples-saved accounting).
    Minutes, not tens of minutes — the full four-family / quant sweep
    stays in the slow job (``run``)."""
    bench(emit, arch="qwen1.5-4b-smoke", slots=2, oversub=2,
          prompt_len=8, max_tokens=12, prefill_chunk=4, wbits_list=(0,))
    bench_paged(emit, base_slots=2, cache_len=24, block_len=8)
    bench_paged_attention(emit)
    bench_quantized(emit)
    bench_mixed_ticks(emit, slots=2, prompt_len=16, max_tokens=12,
                      prefill_chunk=4, max_prefill_tokens=4)
    bench_sampling(emit)
    bench_basecaller(emit)
    bench_read_until(emit)
    bench_dispatch(emit)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=list(FAMILY_ARCHS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--oversub", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--wbits", type=int, nargs="*", default=[0, 8, 4])
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="stop-token id on every request (-1 = none); "
                         "engine evicts at EOS, static decodes to horizon")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI gate: qwen parity + paged-vs-contig "
                         "admission, tiny sizes")
    ap.add_argument("--block-len", type=int, default=8,
                    help="block size for the paged-vs-contiguous "
                         "admission comparison (0 = skip it); other "
                         "sizes follow --slots/--prompt-len/--tokens")
    args = ap.parse_args()

    def emit(name, us, derived=""):
        print(f"{name},{us:.2f},{derived}")

    if args.smoke:
        run_smoke(emit)
        return

    for arch in args.arch:
        # packed-weight variants only exercise attention-family archs'
        # dense layers meaningfully; run them where requested
        bench(emit, arch=arch, slots=args.slots, oversub=args.oversub,
              prompt_len=args.prompt_len, max_tokens=args.tokens,
              prefill_chunk=args.prefill_chunk,
              wbits_list=tuple(args.wbits),
              eos_id=args.eos_id if args.eos_id >= 0 else None,
              tag_arch=len(args.arch) > 1)
    if args.block_len:
        bench_paged(emit, arch=args.arch[0],
                    base_slots=max(args.slots // 2, 1),
                    cache_len=args.prompt_len + args.tokens,
                    block_len=args.block_len,
                    prefill_chunk=args.prefill_chunk)


if __name__ == "__main__":
    main()
